"""Shared harness for the paper-replication benchmarks.

Scale note: this container is one CPU core, so the paper's experiments are
replicated on small same-family GPT-2 configs over the synthetic corpus.
The *mechanisms* under test (instability at aggressive LR/long sequences,
SLW stabilization, variance telemetry, tuning heuristic, token-wise decay)
are scale-free; the headline full-scale numbers are additionally derived
analytically from the compiled dry-run cost model in bench_table2_pareto.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.configs import get_arch, reduced
from repro.configs.base import (BatchWarmupConfig, ModelConfig,
                                OptimizerConfig, RegulatorSpec, SLWConfig,
                                TrainConfig)
from repro.launch.train import TrainResult, train

Row = Tuple[str, float, str]  # (name, us_per_call, derived)

# the benchmark model: a deeper-than-smoke GPT-2 replica that actually shows
# training dynamics on CPU in ~seconds (sized for the 1-core container)
BENCH_MODEL = ModelConfig(
    name="gpt2-bench", family="dense", n_layers=3, d_model=96, n_heads=4,
    n_kv_heads=4, d_ff=384, vocab_size=512, pos_emb="learned",
    norm="layernorm", mlp="gelu", tie_embeddings=True, max_seq_len=512)

SEQ = 192
BATCH = 8


def bench_config(slw: bool = False, lr: float = 1e-3, steps: int = 150,
                 pacing: str = "linear", duration: Optional[int] = None,
                 start_seq: int = 8, batch_warmup: bool = False,
                 schedule: str = "token_cosine", warmup_steps: int = 15,
                 seq: int = SEQ, batch: int = BATCH, grad_clip: float = 1.0,
                 mode: str = "truncate", seed: int = 1234,
                 total_tokens: int = 0,
                 regulators: Tuple[RegulatorSpec, ...] = ()) -> TrainConfig:
    """One bench arm.  `slw` and `batch_warmup` now *compose* through the
    regulator stack (the paper's joint recipe is both at once); `regulators`
    overrides the auto-derived stack entirely (e.g. to add the adaptive
    beyond-paper regulators)."""
    return TrainConfig(
        model=BENCH_MODEL,
        optimizer=OptimizerConfig(
            lr=lr, min_lr=lr / 30, schedule=schedule,
            warmup_steps=warmup_steps,
            warmup_tokens=warmup_steps * batch * seq,
            total_steps=steps,
            total_tokens=total_tokens or steps * batch * seq,
            grad_clip=grad_clip),
        slw=SLWConfig(enabled=slw, pacing=pacing, start_seq_len=start_seq,
                      duration_steps=duration or steps // 3,
                      round_multiple=8, max_buckets=12, mode=mode),
        batch_warmup=BatchWarmupConfig(
            enabled=batch_warmup, start_batch=max(batch // 4, 1),
            warmup_tokens=(duration or steps // 3) * batch * seq // 2),
        regulators=regulators,
        seq_len=seq, global_batch=batch, seed=seed, remat="none",
        eval_interval=10)


def run_arm(name: str, tc: TrainConfig, **kw) -> Tuple[str, TrainResult, float]:
    t0 = time.time()
    res = train(tc, quiet=True, stop_on_nan=False, **kw)
    return name, res, time.time() - t0


def stability_row(name: str, res: TrainResult, wall: float) -> Row:
    s = res.tracker_summary
    derived = (f"spikes={s['spikes']}({100 * s['spike_frac']:.2f}%) "
               f"max_ratio={s['max_loss_ratio']:.2f} "
               f"diverged={res.diverged} "
               f"final_loss={res.loss_history[-1]:.3f}")
    us = wall / max(res.steps, 1) * 1e6
    return (name, us, derived)


def final_ppl(res: TrainResult) -> float:
    if res.val_ppl_history:
        return res.val_ppl_history[-1][1]
    return float("nan")
