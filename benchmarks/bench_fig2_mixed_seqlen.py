"""Fig. 2: early long sequences drive instability.

Three arms at aggressive LR: short-only (seqlen 1/8 of full — stable),
full-length (unstable), and mixed 9:1 short/long (spikes cluster at the
long-sequence steps)."""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from benchmarks.common import BATCH, SEQ, Row, bench_config, run_arm
from repro.configs.base import SLWConfig


def run(quick: bool = False) -> List[Row]:
    steps = 60 if quick else 150
    lr = 0.5
    rows: List[Row] = []

    # short-only: constant seqlen = SEQ/8 via a "two_stage" that never switches
    tc_short = bench_config(slw=True, lr=lr, steps=steps, pacing="two_stage")
    tc_short = dataclasses.replace(
        tc_short, slw=SLWConfig(enabled=True, pacing="two_stage",
                                two_stage_short_len=SEQ // 8,
                                two_stage_switch_step=10 * steps,
                                duration_steps=10 * steps,
                                round_multiple=8))
    name, res, wall = run_arm("fig2/short_only", tc_short)
    rows.append((name, wall / max(res.steps, 1) * 1e6,
                 f"spikes={res.tracker_summary['spikes']} "
                 f"max_ratio={res.tracker_summary['max_loss_ratio']:.2f}"))

    name, res_full, wall = run_arm(
        "fig2/full_length", bench_config(slw=False, lr=lr, steps=steps))
    rows.append((name, wall / max(res_full.steps, 1) * 1e6,
                 f"spikes={res_full.tracker_summary['spikes']} "
                 f"max_ratio={res_full.tracker_summary['max_loss_ratio']:.2f}"))

    # mixed: 9 short steps then 1 full step, repeating (paper: 900/100)
    from repro.configs import get_arch
    from repro.launch.train import train
    tc = bench_config(slw=False, lr=lr, steps=steps)
    import repro.launch.train as train_mod
    from repro.core import LossRatioTracker
    from repro.data import DataPipeline, SyntheticCorpus
    import jax, jax.numpy as jnp
    from repro.launch import steps as steps_lib
    from repro.models import model_zoo
    from repro.optim import lr_at
    import time as _t

    model = model_zoo.build_model(tc.model, dtype=jnp.float32, remat="none")
    state = steps_lib.init_train_state(jax.random.PRNGKey(0), tc.model)
    corpus = SyntheticCorpus(vocab_size=tc.model.vocab_size, seq_len=SEQ)
    pipe = DataPipeline(corpus, BATCH, model_cfg=tc.model)
    step_fn = jax.jit(steps_lib.make_train_step(model, tc.optimizer),
                      donate_argnums=(0,))
    tracker = LossRatioTracker()
    long_step_spikes = 0
    t0 = _t.time()
    tokens = 0
    for step in range(steps):
        long_step = (step % 10) == 9
        batch = pipe.batch_at(step)
        s_t = SEQ if long_step else SEQ // 8
        batch = {k: v[:, :s_t] for k, v in batch.items()}
        lr_now = lr_at(tc.optimizer, step, tokens)
        state, metrics = step_fn(state, batch, np.float32(lr_now))
        tokens += BATCH * s_t
        loss = float(metrics["loss"])
        ratio = tracker.update(loss) if np.isfinite(loss) else 10.0
        if ratio > 1.2 and long_step:
            long_step_spikes += 1
    s = tracker.summary()
    rows.append(("fig2/mixed_9short_1long", (_t.time() - t0) / steps * 1e6,
                 f"spikes={s['spikes']} at_long_steps={long_step_spikes} "
                 f"max_ratio={s['max_loss_ratio']:.2f} "
                 f"(paper: spikes cluster at long-seq steps)"))
    return rows
