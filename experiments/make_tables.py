"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
dry-run artifacts.

    PYTHONPATH=src:. python experiments/make_tables.py > experiments/tables.md
"""
import glob
import json
import os
import sys

sys.path.insert(0, "src")
sys.path.insert(0, ".")

from repro.roofline import build_report
from repro.configs import get_arch, ASSIGNED

DIR = "experiments/dryrun"


def load(path):
    with open(path) as f:
        return json.load(f)


def main():
    # ---- §Dry-run table -----------------------------------------------------
    print("### Dry-run compile matrix (full configs, ShapeDtypeStructs only)\n")
    print("| arch | shape | kind | mesh | chips | compile s | HLO lines | "
          "arg bytes/dev | temp bytes/dev | fallbacks |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    rows = []
    for path in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        if ".measure" in path or path.endswith("rowlocal.json") \
                or path.endswith("fsdppure.json") or path.endswith("servetp.json"):
            continue
        r = load(path)
        mem = r.get("memory", {})
        rows.append(r)
        fb = len(set(r.get("sharding_fallbacks", [])))
        print(f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['mesh']} | "
              f"{r['chips']} | {r['compile_s']:.1f} | {r['hlo_lines']} | "
              f"{mem.get('argument_bytes', 0)/2**30:.2f} GiB | "
              f"{mem.get('temp_bytes', 0)/2**30:.2f} GiB | {fb} |")
    n_single = sum(1 for r in rows if r["mesh"] == "single")
    n_multi = sum(1 for r in rows if r["mesh"] == "multi")
    print(f"\n{len(rows)} cells compiled ({n_single} single-pod 16x16, "
          f"{n_multi} multi-pod 2x16x16). Documented skips: long_500k for the "
          f"8 pure full-attention archs (see DESIGN.md §Arch-applicability).\n")

    # ---- §Roofline table ----------------------------------------------------
    print("### Roofline (single-pod 16x16 = 256 chips; TPU v5e terms)\n")
    print("| arch | shape | t_compute | t_memory | t_collective | bottleneck |"
          " MODEL_FLOPS | useful ratio | MFU bound |")
    print("|---|---|---|---|---|---|---|---|---|")
    for path in sorted(glob.glob(os.path.join(DIR, "*__single.json"))):
        if ".measure" in path:
            continue
        rec = load(path)
        mpath = path.replace(".json", ".measure.json")
        measure = load(mpath) if os.path.exists(mpath) else None
        rep = build_report(rec, measure)
        s = rep.summary()
        print(f"| {s['arch']} | {s['shape']} | "
              f"{s['t_compute_s']*1e3:.1f} ms | {s['t_memory_s']*1e3:.1f} ms |"
              f" {s['t_collective_s']*1e3:.1f} ms | **{s['bottleneck']}** | "
              f"{s['model_flops']:.2e} | {s['useful_flops_ratio']:.2f} | "
              f"{s['mfu_upper_bound']:.3f} |")

    # ---- §Perf variants -----------------------------------------------------
    print("\n### Perf-iteration variants (measured)\n")
    print("| cell | variant | t_compute | t_memory | t_collective | "
          "bottleneck | MFU bound |")
    print("|---|---|---|---|---|---|---|")
    variants = [
        ("deepseek-moe-16b", "train_4k", "", "baseline (global dispatch)"),
        ("deepseek-moe-16b", "train_4k", "rowlocal", "row-local dispatch"),
        ("moonshot-v1-16b-a3b", "train_4k", "", "baseline (global dispatch)"),
        ("moonshot-v1-16b-a3b", "train_4k", "rowlocal", "row-local dispatch"),
        ("qwen3-32b", "train_4k", "", "baseline (fsdp+TP)"),
        ("qwen3-32b", "train_4k", "fsdppure", "pure-FSDP compute"),
        ("qwen3-32b", "decode_32k", "", "baseline (fsdp rules)"),
        ("qwen3-32b", "decode_32k", "servetp", "serve_tp + seq-sharded cache"),
    ]
    for arch, shape, tag, label in variants:
        base = os.path.join(DIR, f"{arch}__{shape}__single.json")
        suffix = f".measure.{tag}.json" if tag else ".measure.json"
        mpath = os.path.join(DIR, f"{arch}__{shape}__single{suffix}")
        if not (os.path.exists(base) and os.path.exists(mpath)):
            continue
        rep = build_report(load(base), load(mpath))
        s = rep.summary()
        print(f"| {arch}/{shape} | {label} | {s['t_compute_s']*1e3:.1f} ms | "
              f"{s['t_memory_s']*1e3:.1f} ms | {s['t_collective_s']*1e3:.1f} ms"
              f" | {s['bottleneck']} | {s['mfu_upper_bound']:.3f} |")


if __name__ == "__main__":
    main()
