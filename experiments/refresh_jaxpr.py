"""Re-trace jaxpr flops/bytes for existing measure records (fast; keeps the
expensive collective-extrapolation points)."""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=1"
import glob, json, sys
sys.path.insert(0, "src")
import jax, jax.numpy as jnp
from repro.configs import get_arch
from repro.configs.base import OptimizerConfig
from repro.launch import steps as steps_lib
from repro.models import model_zoo
from repro.roofline.jaxpr_cost import analyze_fn

for path in sorted(glob.glob("experiments/dryrun/*.measure*.json")):
    rec = json.load(open(path))
    spec = get_arch(rec["arch"])
    shape = spec.shape(rec["shape"])
    cfg = spec.model
    if rec.get("moe_dispatch") and cfg.family == "moe":
        cfg = cfg.replace(moe_dispatch=rec["moe_dispatch"])
    elif cfg.family == "moe":
        cfg = cfg.replace(moe_dispatch="global")  # pre-flag records
    model = model_zoo.build_model(cfg, dtype=jnp.bfloat16,
                                  remat=rec.get("remat", "full"))
    if shape.kind == "train":
        fn = steps_lib.make_train_step(model, OptimizerConfig(), None)
        state = steps_lib.abstract_train_state(cfg)
        batch = model_zoo.train_batch_specs(cfg, shape.global_batch, shape.seq_len)
        cost = analyze_fn(fn, state, batch, jax.ShapeDtypeStruct((), jnp.float32))
    elif shape.kind == "prefill":
        fn = steps_lib.make_prefill_step(model, None)
        batch = model_zoo.prefill_batch_specs(cfg, shape.global_batch, shape.seq_len)
        cost = analyze_fn(fn, model_zoo.abstract_params(cfg), batch)
    else:
        fn = steps_lib.make_serve_step(model, None)
        cache = model.cache_shapes(shape.global_batch, shape.seq_len)
        tokens = model_zoo.decode_token_specs(shape.global_batch)
        cost = analyze_fn(fn, model_zoo.abstract_params(cfg), cache, tokens)
    rec["jaxpr_flops_global"] = cost.flops
    rec["jaxpr_bytes_global"] = cost.bytes
    rec["jaxpr_flops_by_prim"] = {k: v for k, v in sorted(
        cost.by_prim.items(), key=lambda kv: -kv[1])[:8]}
    json.dump(rec, open(path, "w"), indent=1)
    print("refreshed", os.path.basename(path), f"bytes={cost.bytes:.3e}")
